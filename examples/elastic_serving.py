"""Elastic traversal serving: the ``repro.serve`` subsystem end to end.

Generates a seeded open-loop Poisson arrival trace over an R-MAT graph and
serves it twice with ``TraversalService`` -- once with elastic per-window VM
capacity (activity forecast + Ghaderi queue-drift rule) and once statically
provisioned at ``max_vms`` -- then prints the throughput / sojourn / cost
comparison at several arrival rates.  Every number comes off the simulated
clock, so reruns are bit-for-bit identical.

  PYTHONPATH=src python examples/elastic_serving.py
  PYTHONPATH=src python examples/elastic_serving.py --rates 2 8 32 --queries 200

(The LM decode server lives in ``repro.launch.serve`` -- a separate front
end; this demo is the graph-query one.)
"""

import argparse
import dataclasses

from repro.graph.generators import rmat_graph
from repro.graph.partition import hash_partition
from repro.serve import ServiceConfig, TraversalService, poisson_trace


def serve_at_rate(pg, rate, n_queries, cfg, seed):
    trace = poisson_trace(n_queries, rate, pg.graph.n_vertices, seed=seed)
    elastic = TraversalService(pg, config=cfg).run(trace)
    static = TraversalService(
        pg, config=dataclasses.replace(cfg, static_vms=cfg.max_vms)
    ).run(trace)
    return elastic, static


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=9, help="R-MAT log2 vertices")
    ap.add_argument("--degree", type=int, default=8)
    ap.add_argument("--parts", type=int, default=8)
    ap.add_argument("--queries", type=int, default=120)
    ap.add_argument(
        "--rates", type=float, nargs="+", default=[5.0, 20.0, 80.0],
        help="arrival rates, queries/sec of simulated time",
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    g = rmat_graph(args.scale, args.degree, seed=args.seed)
    pg = hash_partition(g, args.parts, seed=args.seed)
    # tau_scale lifts the microsecond-scale modeled supersteps into a regime
    # where the demo rates are meaningful while a whole run's busy span still
    # fits inside one billing quantum (delta=60s) -- otherwise every elastic
    # session spans >delta and bills the same as static regardless of capacity
    cfg = ServiceConfig(s_batch=8, window=8, tau_scale=1e3)
    print(
        f"serving R-MAT 2^{args.scale} (deg {args.degree}, {args.parts} "
        f"parts): {args.queries} queries per rate, elastic "
        f"[{cfg.min_vms}..{cfg.max_vms}] VMs vs static {cfg.max_vms}"
    )
    hdr = (
        f"{'rate':>6s} {'mode':>8s} {'done':>5s} {'qps':>7s} {'p50':>7s} "
        f"{'p99':>7s} {'occ':>5s} {'vms':>5s} {'quanta':>6s} {'cost/1k':>8s}"
    )
    print(hdr)
    for rate in args.rates:
        elastic, static = serve_at_rate(pg, rate, args.queries, cfg, args.seed)
        for mode, r in (("elastic", elastic), ("static", static)):
            print(
                f"{rate:6.1f} {mode:>8s} {r.completed:5d} "
                f"{r.queries_per_sec:7.2f} {r.sojourn_p50:7.3f} "
                f"{r.sojourn_p99:7.3f} {r.occupancy:5.2f} "
                f"{r.capacity_mean:5.2f} {r.cost.cost_quanta:6d} "
                f"{r.cost_per_1k_queries:8.1f}"
            )
    print(
        "\nelastic capacity rides the arrival rate: near-static latency "
        "(within the scheduler's stretch bound) at a fraction of the billed "
        "quanta when the queue is short, ramping to full capacity under "
        "backlog."
    )


if __name__ == "__main__":
    main()
