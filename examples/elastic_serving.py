"""Elastic LM serving via the paper's placement layer (arch-applicability
demo, DESIGN s4): the same TimeFunction -> placement -> billing machinery
schedules model *replicas* against a non-stationary request load.

"Partitions" are serving shards (KV-cache groups), "supersteps" are
scheduling windows, and tau_i^s is the predicted busy-time of shard i in
window s from a diurnal load model.  Strategies then trade makespan (p99
latency headroom) against core-minutes exactly as for graph partitions.

  PYTHONPATH=src python examples/elastic_serving.py
"""

import numpy as np

from repro.core import BillingModel, TimeFunction, evaluate, STRATEGIES


def diurnal_load(n_windows: int = 48, n_shards: int = 16, seed: int = 0):
    """Predicted busy seconds per (window, shard): sinusoidal diurnal traffic
    with bursty noise, consistent-hashed across shards."""
    rng = np.random.default_rng(seed)
    t = np.arange(n_windows)
    base = 30.0 * (1.0 + 0.9 * np.sin(2 * np.pi * t / n_windows - np.pi / 2))
    shard_weight = rng.dirichlet(np.full(n_shards, 8.0))
    tau = base[:, None] * shard_weight[None, :] * n_shards
    tau *= rng.lognormal(0.0, 0.25, tau.shape)
    tau[tau < 1.0] = 0.0  # idle shards in low-traffic windows
    return TimeFunction(tau)


def main():
    tf = diurnal_load()
    model = BillingModel(delta=60.0)
    print(
        f"serving load: {tf.n_supersteps} windows x {tf.n_parts} shards, "
        f"{(tf.tau > 0).mean():.0%} shard-windows active"
    )
    print(f"{'strategy':10s} {'windows-over-SLO':>17s} {'cost':>5s} {'peak replicas':>14s}")
    base = None
    for name, strat in STRATEGIES.items():
        r = evaluate(strat(tf), model)
        over = r.makespan / r.t_min - 1
        base = base or r.cost_quanta
        print(
            f"{name:10s} {over:16.1%} {r.cost_quanta:5d} {r.peak_vms:14d}"
        )
    print(
        "\nelastic replica scheduling rides the diurnal curve; pinned"
        " strategies avoid KV-cache migration (the serving analogue of the"
        " paper's data-movement cost)."
    )


if __name__ == "__main__":
    main()
