"""Quickstart: the paper in one minute.

Builds a synthetic road graph, partitions it, runs the subgraph-centric BFS
to get the time function A, plans every placement strategy, and prints the
makespan/cost table (the paper's Fig. 3 in miniature).

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (
    BillingModel,
    TimeFunction,
    evaluate,
    STRATEGIES,
    build_metagraph,
    opt_placement,
)
from repro.core.metagraph import predict_time_function
from repro.graph import bfs_grow_partition, road_grid_graph
from repro.graph.bsp import run_sssp


def main():
    print("== build + partition graph " + "=" * 40)
    g = road_grid_graph(80, 80, seed=1)
    pg = bfs_grow_partition(g, 8, seed=2)
    print(
        f"graph: {g.n_vertices} vertices, {g.n_edges} edges; "
        f"8 partitions, {pg.n_subgraphs} subgraphs, "
        f"edge cut {pg.edge_cut_fraction:.1%}, balance {pg.balance_factor():.3f}"
    )

    print("\n== run subgraph-centric BFS (collect time function A) " + "=" * 12)
    dist, trace = run_sssp(pg, source=0)
    print(
        f"BFS converged in {trace.n_supersteps} supersteps; "
        f"mean active partition fraction {trace.mean_active_fraction():.0%} "
        f"(the paper's Fig-2 under-utilization)"
    )
    tf = TimeFunction.from_trace(trace).scaled_to_tmin(90.0)

    print("\n== metagraph a-priori prediction " + "=" * 34)
    mg = build_metagraph(pg)
    pred_tf, sched = predict_time_function(pg, 0, mg=mg)
    print(
        f"metagraph: {mg.n_meta} meta-vertices / {mg.n_meta_edges} meta-edges; "
        f"predicts {sched.n_supersteps} supersteps (actual {trace.n_supersteps})"
    )

    print("\n== placement strategies (delta = 60s billing) " + "=" * 21)
    model = BillingModel(delta=60.0)
    print(f"{'strategy':10s} {'makespan':>9s} {'T/Tmin':>7s} {'cost':>5s} "
          f"{'core-secs':>10s} {'peak VMs':>9s}")
    for name, strat in STRATEGIES.items():
        r = evaluate(strat(tf), model)
        print(
            f"{name:10s} {r.makespan:8.1f}s {r.makespan_over_tmin:7.3f} "
            f"{r.cost_quanta:5d} {r.core_secs:10.1f} {r.peak_vms:9d}"
        )
    r_dm = evaluate(
        opt_placement(tf), model, data_movement=True,
        partition_bytes=pg.partition_bytes() * 2000.0,
    )
    print(
        f"{'opt-dm':10s} {r_dm.makespan:8.1f}s {r_dm.makespan_over_tmin:7.3f} "
        f"{r_dm.cost_quanta:5d} {r_dm.core_secs:10.1f} {r_dm.peak_vms:9d}"
        f"   (movement {r_dm.data_move_secs:.0f}s)"
    )
    print("\nelastic strategies cut cost vs the 8-VM default while OPT/FFD "
          "hold makespan at T_Min -- the paper's headline result.")


if __name__ == "__main__":
    main()
